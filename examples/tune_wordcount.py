"""The paper, end-to-end: auto-tune WordCount's 12 parameters with the
paper's two algorithms plus the model-based TPE strategy, all on measured
wall-clock time, then compare (paper §X/§XI).

    PYTHONPATH=src python examples/tune_wordcount.py
"""
from pathlib import Path

from repro.apps.wordcount import make_evaluator, WORDCOUNT_SPACE
from repro.core import tune


def main():
    evaluator = make_evaluator()
    log = Path("results/examples/wordcount_tune.jsonl")

    gsft = tune("train", "gsft", evaluator, space=WORDCOUNT_SPACE, log_path=log,
                active_params=["replication", "block_tokens", "num_map_tasks"],
                samples_per_param=3)
    crs = tune("train", "crs", evaluator, space=WORDCOUNT_SPACE, log_path=log,
               m=10, k=3, max_rounds=4, seed=0)
    tpe = tune("train", "tpe", evaluator, space=WORDCOUNT_SPACE, log_path=log,
               max_trials=40, seed=0)

    print(f"default execution time : {gsft.default_time*1e3:8.1f} ms")
    print(f"GSFT  best             : {gsft.best_time*1e3:8.1f} ms "
          f"(-{gsft.reduction_pct:.1f}%, {gsft.evaluations} trials)")
    print(f"CRS   best             : {crs.best_time*1e3:8.1f} ms "
          f"(-{crs.reduction_pct:.1f}%, {crs.evaluations} trials)")
    print(f"TPE   best             : {tpe.best_time*1e3:8.1f} ms "
          f"(-{tpe.reduction_pct:.1f}%, {tpe.evaluations} trials)")
    print("\nGSFT best config (non-defaults):")
    for k, v in gsft.best_config.items():
        if v != WORDCOUNT_SPACE.param(k).default:
            print(f"  {k} = {v}")
    print(f"\ntrial log -> {log}")


if __name__ == "__main__":
    main()
