"""Multi-fidelity ASHA on the paper's WordCount job, next to a full-fidelity
TPE session with the same search width.

ASHA screens every candidate on a cheap corpus prefix (rung fidelities
``min_fidelity * eta^k``) and promotes only the top ``1/eta`` of each rung —
asynchronously, with no round barrier — so most of the budget is spent at a
fraction of a full measurement. The session prints the per-rung survival
table: 32 configs enter at 1/64 of the corpus, 4 reach a full measurement.

    PYTHONPATH=src python examples/asha_wordcount.py
"""
from pathlib import Path

from repro.apps.wordcount import make_evaluator
from repro.core import Study

STUDY_DIR = Path("results/studies/wordcount_asha")


def main():
    study = Study.open(STUDY_DIR)
    evaluator = make_evaluator(repeats=4)

    # full-fidelity yardstick: every TPE trial pays a complete measurement
    tpe = study.optimize("wordcount", "tpe", evaluator, budget=32, seed=0)

    # same width (32 distinct configs), but entered at 1/64 fidelity; the
    # steep eta=4 ladder keeps the eager top-1/eta rule from over-promoting
    asha = study.optimize(
        "wordcount", "asha", evaluator,
        budget=32, seed=0, inner="tpe", eta=4.0, min_fidelity=1.0 / 64.0,
    )

    print(f"TPE  best (32 full trials) : {tpe.best_time * 1e3:8.1f} ms "
          f"(-{tpe.reduction_pct:.1f}%)")
    print(f"ASHA best (rung ladder)    : {asha.best_time * 1e3:8.1f} ms "
          f"(-{asha.reduction_pct:.1f}%, "
          f"measured at fidelity {asha.detail.best_fidelity:g})")

    print("\nrung  fidelity  launched  completed  promoted")
    for row in asha.summary()["rungs"]:
        print(f"{row['rung']:4d}  {row['fidelity']:8g}  {row['launched']:8d}"
              f"  {row['completed']:9d}  {row['promoted']:8d}")

    paid = sum(r["fidelity"] * r["completed"] for r in asha.summary()["rungs"])
    print(f"\nfidelity-weighted cost: {paid:.1f} full-trial equivalents "
          f"for {asha.detail.proposals} configs screened "
          f"(vs 32.0 for the TPE session)")
    print(f"study persisted at {STUDY_DIR} — rerun me for a zero-cost replay")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
