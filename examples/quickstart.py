"""Quickstart: build a model from the registry, run one train step, then
prefill + decode a few tokens — all on CPU with a reduced (smoke) config.

    PYTHONPATH=src python examples/quickstart.py [--arch gemma2-9b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.archs import ARCH_NAMES, get_arch
from repro.configs.base import RunConfig, ShapeConfig
from repro.models.model import Model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b", choices=ARCH_NAMES)
    args = ap.parse_args()

    arch = get_arch(args.arch, smoke=True)
    model = Model(arch, RunConfig())
    params = model.init_params(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{arch.name} (smoke): {n:,} params, {arch.num_layers} layers, "
          f"family={arch.family}")

    # --- one training step (loss + grads through the full stack)
    shape = ShapeConfig("quickstart", 64, 2, "train")
    batch = model.make_inputs(shape)
    loss_fn = jax.jit(lambda p, b: model.loss(p, b)[0])
    loss = loss_fn(params, batch)
    grads = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))(params, batch)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)))
    print(f"train: loss={float(loss):.4f} grad_norm={float(gnorm):.4f}")

    # --- prefill + greedy decode
    prompt = ShapeConfig("prompt", 16, 2, "prefill")
    pbatch = model.make_inputs(prompt)
    logits, caches = jax.jit(lambda p, b: model.prefill(p, b))(params, pbatch)

    def grow(path, x):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v", "ks", "vs"):
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, 8)
            return jnp.pad(x, pad)
        return x

    caches = jax.tree_util.tree_map_with_path(grow, caches)
    decode = jax.jit(lambda p, c, b: model.decode_step(p, c, b))
    toks = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [toks]
    for i in range(7):
        logits, caches = decode(params, caches, {
            "tokens": toks, "cache_len": jnp.asarray(16 + i, jnp.int32)})
        toks = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(toks)
    print("decoded ids:", jnp.concatenate(out, 1)[0].tolist())


if __name__ == "__main__":
    main()
