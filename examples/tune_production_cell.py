"""Tune a production-mesh cell with the ROOFLINE evaluator — the tuner
searching the 12-knob training space for qwen2-72b/train_4k on 256 chips
(AOT: every trial is a lower+compile, no execution).

    PYTHONPATH=src python examples/tune_production_cell.py \
        --arch qwen2-72b --shape train_4k --algorithm gsft
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
from pathlib import Path

from repro.configs.archs import ARCH_NAMES, get_arch
from repro.configs.base import SHAPES
from repro.core import SPACES, tune
from repro.core.evaluators import RooflineEvaluator


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b", choices=ARCH_NAMES)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--algorithm", default="gsft", choices=["gsft", "crs"])
    args = ap.parse_args()

    arch = get_arch(args.arch)
    shape = SHAPES[args.shape]
    platform = "train" if shape.kind == "train" else "serve"
    space = SPACES[platform]
    evaluator = RooflineEvaluator(arch, shape, space, chips=256)

    kwargs = (
        dict(active_params=["mesh_model_parallel", "microbatch_size", "remat_policy"],
             samples_per_param=3)
        if args.algorithm == "gsft"
        else dict(m=8, k=3, max_rounds=3)
    )
    out = tune(platform, args.algorithm, evaluator,
               log_path=Path(f"results/examples/tune_{args.arch}_{args.shape}.jsonl"),
               **kwargs)
    print(json.dumps(out.summary(), indent=1, default=str))


if __name__ == "__main__":
    main()
