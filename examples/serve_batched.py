"""Batched serving example: prefill a request batch and decode with greedy
sampling (wraps the production serve driver at smoke scale).

    PYTHONPATH=src python examples/serve_batched.py --arch phi3.5-moe-42b-a6.6b
"""
import argparse
import sys

from repro.launch.serve import main as serve_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3.5-moe-42b-a6.6b")
    args = ap.parse_args()
    return serve_main([
        "--arch", args.arch, "--smoke", "--batch", "4",
        "--prompt-len", "32", "--max-new", "16",
    ])


if __name__ == "__main__":
    sys.exit(main())
