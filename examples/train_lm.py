"""End-to-end training driver: a ~15M-parameter llama-family model trained
for a few hundred steps on CPU, with checkpointing, failure injection, and
restart — the full production loop at laptop scale.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses

import jax

from repro.compat import set_mesh as compat_set_mesh

from repro.configs.archs import get_arch
from repro.configs.base import RunConfig, ShapeConfig
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import PipelineConfig, SyntheticLMPipeline
from repro.distributed.steps import init_train_state, make_train_step
from repro.ft.runner import ResilientTrainer, RunnerConfig
from repro.launch.mesh import make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[60])
    args = ap.parse_args()

    # a mid-size smoke model (~15M params): llama family, 4 layers, d=256
    arch = dataclasses.replace(
        get_arch("llama3.2-1b", smoke=True),
        num_layers=4, d_model=256, num_heads=8, num_kv_heads=4, d_ff=1024,
        vocab_size=32768,
    )
    shape = ShapeConfig("train_lm", 128, 8, "train")
    run = RunConfig(mesh_model_parallel=1, learning_rate=1e-3)
    mesh = make_host_mesh(model_parallel=1)

    with compat_set_mesh(mesh):
        bundle = make_train_step(arch, run, shape, mesh)
        state = init_train_state(bundle)
        n = sum(x.size for x in jax.tree.leaves(state["params"]))
        print(f"model: {n/1e6:.1f}M params; {args.steps} steps of "
              f"{shape.global_batch}×{shape.seq_len} tokens")
        trainer = ResilientTrainer(
            step_fn=bundle.jit(),
            state=state,
            pipeline=SyntheticLMPipeline(arch, shape, PipelineConfig(seed=0)),
            ckpt=CheckpointManager(args.ckpt_dir, keep_n=2),
            cfg=RunnerConfig(total_steps=args.steps, checkpoint_every=50),
            fail_at=args.fail_at,
        )
        trainer.run()

    h = trainer.history
    print(f"loss: step0={h[0]['loss']:.4f} -> step{h[-1]['step']}={h[-1]['loss']:.4f} "
          f"(restarts={trainer.restarts})")
    k = max(len(h) // 10, 1)
    for row in h[::k]:
        print(f"  step {row['step']:4d} loss {row['loss']:.4f} ({row['dt']*1e3:.0f} ms)")
    assert h[-1]["loss"] < h[0]["loss"]
    print("OK")


if __name__ == "__main__":
    main()
