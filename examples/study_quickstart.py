"""Study quickstart: one persistent study over the paper's WordCount job.

    create -> optimize(gsft) -> optimize(tpe, warm-started) -> report

Every session shares the study's evaluation cache, so the TPE session gets
the GSFT session's measurements as free model evidence (not budget theft),
and re-running this script replays everything for zero fresh evaluations.
Interrupt it mid-run and `Study.load(STUDY_DIR).resume(evaluator=...)` pays
only the unpaid remainder.

    PYTHONPATH=src python examples/study_quickstart.py
"""
import json
from pathlib import Path

from repro.apps.wordcount import make_evaluator
from repro.core import EngineConfig, Study

STUDY_DIR = Path("results/studies/wordcount_quickstart")


def main():
    study = Study.open(STUDY_DIR, engine=EngineConfig(workers=2))
    evaluator = make_evaluator()

    # session 1 — the paper's Grid Search with Finer Tuning on the
    # most-influential WordCount knobs
    gsft = study.optimize(
        "wordcount", "gsft", evaluator,
        active_params=["replication", "block_tokens", "num_map_tasks"],
        samples_per_param=3,
    )
    print(f"[gsft] reduction {gsft.reduction_pct:.1f}% "
          f"({gsft.evaluations} evaluations, "
          f"{gsft.cache_stats['cache_hits']} replayed)")

    # session 2 — TPE over the full knob set; the gsft records above seed its
    # observation model through on_study_attach, free of budget
    tpe = study.optimize("wordcount", "tpe", evaluator, budget=24, seed=0)
    print(f"[tpe]  reduction {tpe.reduction_pct:.1f}% "
          f"(warm-started from {tpe.detail.warm_started} cached observations)")

    # the paper's reduction table, one row per session + best per platform
    print(json.dumps(study.report(), indent=1, default=str))
    print(f"study persisted at {STUDY_DIR} — rerun me for a zero-cost replay")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
