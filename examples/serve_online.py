"""Online serving tuner on the scripted "drift" trace, end to end.

The trace starts in a regime where the default serving config is optimal,
then shifts to short prompts where a smaller ``attn_block_kv`` and an int8
KV cache win. The controller keeps the incumbent config on the majority of
decode windows throughout, probes one strategy-proposed candidate at a time
inside the p99 safety envelope, and — after the shift — promotes a
measurably better baseline. Every guard decision lands in the study journal,
so the run is auditable afterwards like any offline session.

    PYTHONPATH=src python examples/serve_online.py

Equivalent CLI:  python -m repro.launch.serve --online-tune \
    --study results/studies/serve_online --traffic drift --strategy tpe
"""
from pathlib import Path

from repro.core import Study
from repro.core.space import SERVE_SPACE
from repro.core.strategies import make_strategy
from repro.core.transfer import snap_into_space
from repro.serving import (
    DecodeWindowMonitor,
    GuardConfig,
    OnlineController,
    OnlineJournal,
    SyntheticServeModel,
    scripted_trace,
    surviving_baseline,
)

STUDY_DIR = Path("results/studies/serve_online")
PLATFORM = "serve-online/drift"


def main():
    study = Study.open(STUDY_DIR)
    guard = GuardConfig(safety_p99=1.25, slice_frac=0.2, probation_windows=3)

    # a previous run's promoted baseline survives; first run starts at the
    # space defaults
    baseline = (surviving_baseline(study, PLATFORM)
                or snap_into_space(SERVE_SPACE, {}))
    strategy = make_strategy("tpe", SERVE_SPACE, max_trials=32,
                             round_size=1, seed=0)
    model = SyntheticServeModel(scripted_trace("drift"), seed=0)

    with study:
        journal = OnlineJournal(study, PLATFORM, algorithm="online-tpe",
                                guard=guard, baseline=baseline)
        controller = OnlineController(SERVE_SPACE, strategy, baseline,
                                      guard=guard, journal=journal,
                                      platform=PLATFORM)
        monitor = DecodeWindowMonitor()  # clock-free: scripted latencies
        for w in range(model.total_windows):
            plan = controller.next_window()
            phase = model.phase_at(w)
            monitor.begin_window()
            for latency in model.latencies(w, plan.config, plan.slice):
                monitor.record(latency, tokens=phase.batch)
            stats = monitor.end_window()
            controller.observe(plan, stats)
            if plan.slice == "candidate":
                print(f"window {w:3d} [{phase.name:>13s}] candidate "
                      f"#{plan.candidate_id}: p99 {stats.p99 * 1e3:.3f}ms "
                      f"(baseline {controller.baseline_p99 * 1e3:.3f}ms)")
        summary = controller.summary()
        journal.finish(summary)

    print(f"\nwindows: {summary['windows']} "
          f"(baseline {summary['windows_baseline']}, "
          f"candidate {summary['windows_candidate']}) | "
          f"rollbacks {summary['rollbacks']}, "
          f"promotions {summary['promotions']}, "
          f"demotions {summary['demotions']}")
    print(f"windowed p99: {summary['default_time_s'] * 1e3:.3f}ms -> "
          f"{summary['best_time_s'] * 1e3:.3f}ms "
          f"({summary['reduction_pct']}% reduction)")
    best = summary["best_config"]
    print(f"surviving baseline: attn_block_kv={best['attn_block_kv']}, "
          f"kv_cache_dtype={best['kv_cache_dtype']}")

    print("\nstudy sessions:")
    for row in Study.load(STUDY_DIR).report()["sessions"]:
        print(f"  session {row['session']}: mode={row.get('mode', 'offline')} "
              f"algo={row['algorithm']} status={row['status']} "
              f"promotions={row.get('promotions')} "
              f"rollbacks={row.get('rollbacks')}")


if __name__ == "__main__":
    main()
